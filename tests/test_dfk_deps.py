"""DFK batched dependency resolution: wide fan-in/fan-out correctness
under concurrency, upstream-failure propagation through the dependency
manager, and the flush-vs-flusher race (the old per-window Timer's
double-submit hazard, now a persistent flusher thread)."""
import threading
import time

import pytest

from repro.core import (DataFlowKernel, Executor, PilotDescription,
                        RPEXExecutor, python_app)

pytestmark = pytest.mark.timeout(120)    # race tests must fail, not wedge


class ManualExecutor(Executor):
    """Records every submission; tasks run only when the test says so —
    full control over producer-completion timing and batch boundaries."""

    label = "manual"
    supports_bulk = True

    def __init__(self):
        self.lock = threading.Lock()
        self.pending = []          # (ParslTask, AppFuture) not yet run
        self.bulk_batches = []     # list of batch sizes, in arrival order
        self.singles = 0

    def submit(self, pt, fut):
        with self.lock:
            self.singles += 1
            self.pending.append((pt, fut))

    def submit_bulk(self, pairs):
        with self.lock:
            self.bulk_batches.append(len(pairs))
            self.pending.extend(pairs)

    def run_pending(self):
        with self.lock:
            batch, self.pending = self.pending, []
        for pt, fut in batch:
            try:
                fut.set_result(pt.fn(*pt.args, **pt.kwargs))
            except BaseException as e:  # noqa: BLE001
                fut.set_exception(e)
        return len(batch)

    def wait_for(self, pred, timeout=5.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self.lock:
                if pred(self):
                    return True
            time.sleep(0.002)
        return False


# ------------------------------ fan-in ---------------------------------- #

def test_wide_fanin_launches_exactly_once_under_concurrency():
    """N producers completing concurrently in agent worker threads race
    their decrements on the consumer's dep counter; the consumer must
    launch exactly once with all inputs resolved."""
    rpex = RPEXExecutor(PilotDescription(n_slots=4))
    try:
        launches = []

        @python_app
        def produce(i):
            return i

        @python_app
        def aggregate(xs):
            launches.append(len(xs))
            return sum(xs)

        with DataFlowKernel(executors={"rpex": rpex}):
            for round_ in range(5):
                futs = [produce(i) for i in range(64)]
                total = aggregate(futs).result(timeout=30)
                assert total == sum(range(64))
        assert launches == [64] * 5, "aggregate launched more than once"
    finally:
        rpex.shutdown()


def test_fanout_launches_in_one_bulk_pass():
    """One producer feeding N consumers: when it completes, the ready
    batch flows into the per-executor bulk buffer and is drained as one
    submit_bulk pass — not N callback chains or N timer windows."""
    ex = ManualExecutor()

    def produce():
        return 7

    def consume(x, i):
        return x * 10 + i

    # bulk_window far beyond the test timeout: only the immediate
    # dependency-ready flush can deliver the consumer batch
    with DataFlowKernel(executors={"manual": ex}, bulk=True,
                        bulk_window=30.0) as dfk:
        fp = dfk.submit(produce)
        futs = [dfk.submit(consume, (fp, i)) for i in range(128)]
        dfk.flush()                      # push the producer itself
        assert ex.run_pending() == 1     # producer completes...
        assert ex.wait_for(lambda e: sum(e.bulk_batches) >= 129), \
            "dependency-ready batch never flushed"
        assert max(ex.bulk_batches) == 128, (
            f"fan-out split into {ex.bulk_batches} instead of one pass")
        ex.run_pending()
        assert sorted(f.result(timeout=5) for f in futs) == \
            [70 + i for i in range(128)]


def test_deep_chain_through_batched_manager():
    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        @python_app
        def inc(x):
            return x + 1

        with DataFlowKernel(executors={"rpex": rpex}, bulk=True) as dfk:
            f = inc(0)
            for _ in range(39):
                f = inc(f)
            dfk.flush()
            assert f.result(timeout=30) == 40
    finally:
        rpex.shutdown()


def test_dep_on_just_completed_future_races():
    """Producers that complete during consumer registration must still
    decrement exactly once — stress the done-at-registration path."""
    rpex = RPEXExecutor(PilotDescription(n_slots=4))
    try:
        @python_app
        def quick(i):
            return i

        @python_app
        def follow(x):
            return x + 1000

        with DataFlowKernel(executors={"rpex": rpex}):
            pairs = []
            for i in range(200):
                fp = quick(i)          # may complete before follow(fp)
                pairs.append((i, follow(fp)))
            for i, f in pairs:
                assert f.result(timeout=30) == i + 1000
    finally:
        rpex.shutdown()


# ---------------------- cross-producer coalescing ------------------------ #

def test_near_simultaneous_producer_completions_coalesce():
    """Producers completing while a decrement drain is in flight are
    combined into that drain (their threads return immediately) instead
    of each paying a contended pass — and the combined pass still
    launches every ready consumer exactly once."""
    ex = ManualExecutor()
    with DataFlowKernel(executors={"manual": ex}) as dfk:
        p1 = dfk.submit(lambda: 1)
        p2 = dfk.submit(lambda: 2)
        c = dfk.submit(lambda a, b: a + b, (p1, p2))

        # simulate an in-flight drain: both producers complete while the
        # drainer flag is held, so their done-callbacks must enqueue and
        # bail out without touching the consumer counters
        with dfk._dep_lock:
            dfk._dep_draining = True
        ex.run_pending()                     # completes p1 and p2
        assert p1.done() and p2.done()
        assert len(dfk._producer_q) == 2, "completions were not queued"
        assert dfk.dep_coalesced == 2
        assert not ex.pending, "consumer launched during a foreign drain"

        # release the flag; the next completion drains the whole backlog
        # in one combined pass (duplicate producer entries are idempotent)
        with dfk._dep_lock:
            dfk._dep_draining = False
        dfk._on_producer_done(p1)
        assert ex.wait_for(lambda e: len(e.pending) == 1), \
            "combined drain never launched the consumer"
        ex.run_pending()
        assert c.result(timeout=5) == 3


def test_coalesced_wide_fanin_launches_once_and_correctly():
    """The combining path under real concurrency: many producers finish
    across agent workers; whatever interleaving the drainer sees, each
    consumer launches exactly once with all inputs resolved (and at
    least some completions should have combined)."""
    rpex = RPEXExecutor(PilotDescription(n_slots=4))
    try:
        @python_app
        def produce(i):
            return i

        @python_app
        def aggregate(xs):
            return sum(xs)

        with DataFlowKernel(executors={"rpex": rpex}) as dfk:
            totals = []
            for _ in range(5):
                futs = [produce(i) for i in range(64)]
                totals.append(aggregate(futs).result(timeout=30))
            assert totals == [sum(range(64))] * 5
            # not asserted deterministically (scheduling-dependent), but
            # record the stat so regressions in the combining path show
            # up in -v output
            print(f"dep_coalesced={dfk.dep_coalesced}")
    finally:
        rpex.shutdown()


# ------------------------ failure propagation --------------------------- #

@pytest.mark.parametrize("bulk", [False, True])
def test_upstream_failure_propagates_and_consumer_never_runs(bulk):
    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        ran = []

        @python_app
        def boom():
            raise ValueError("upstream boom")

        @python_app
        def after(x):
            ran.append(x)
            return x

        with DataFlowKernel(executors={"rpex": rpex}, bulk=bulk) as dfk:
            f1 = boom()
            f2 = after(f1)
            if bulk:
                dfk.flush()
            with pytest.raises(ValueError, match="upstream boom"):
                f2.result(timeout=10)
        assert ran == []
    finally:
        rpex.shutdown()


def test_partial_failure_wide_fanin():
    """One failed producer out of many fails the consumer (with the
    producer's error), after all producers settle."""
    rpex = RPEXExecutor(PilotDescription(n_slots=4))
    try:
        ran = []

        @python_app
        def produce(i):
            if i == 13:
                raise RuntimeError("producer 13 failed")
            return i

        @python_app
        def aggregate(xs):
            ran.append(1)
            return sum(xs)

        with DataFlowKernel(executors={"rpex": rpex}):
            futs = [produce(i) for i in range(32)]
            fagg = aggregate(futs)
            with pytest.raises(RuntimeError, match="producer 13"):
                fagg.result(timeout=30)
        assert ran == []
    finally:
        rpex.shutdown()


def test_failure_nested_inside_structure_propagates():
    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        @python_app
        def boom():
            raise KeyError("nested boom")

        @python_app
        def consume(payload):
            return payload

        with DataFlowKernel(executors={"rpex": rpex}):
            f = consume({"results": [boom()]})   # future inside dict/list
            with pytest.raises(KeyError):
                f.result(timeout=10)
    finally:
        rpex.shutdown()


# ------------------------- flush-vs-flusher race ------------------------- #

def test_manual_flush_vs_flusher_never_double_submits():
    """Regression for the Timer-era race: explicit flush() calls hammering
    the DFK while the background flusher drains deadline batches must
    submit every task exactly once."""
    ex = ManualExecutor()
    done = threading.Event()

    def runner():                      # complete whatever arrives
        while not done.is_set():
            ex.run_pending()
            time.sleep(0.001)
        ex.run_pending()

    run_th = threading.Thread(target=runner, daemon=True)
    run_th.start()
    try:
        with DataFlowKernel(executors={"manual": ex}, bulk=True,
                            bulk_window=0.001) as dfk:
            futs = []
            flock = threading.Lock()
            stop_flush = threading.Event()

            def hammer():
                while not stop_flush.is_set():
                    dfk.flush()

            flushers = [threading.Thread(target=hammer, daemon=True)
                        for _ in range(2)]
            for t in flushers:
                t.start()

            def feeder(base):
                for i in range(100):
                    f = dfk.submit(lambda v=base + i: v)
                    with flock:
                        futs.append(f)

            feeders = [threading.Thread(target=feeder, args=(k * 1000,))
                       for k in range(3)]
            for t in feeders:
                t.start()
            for t in feeders:
                t.join()
            results = sorted(f.result(timeout=30) for f in futs)
            stop_flush.set()
            for t in flushers:
                t.join(timeout=5)
        want = sorted(k * 1000 + i for k in range(3) for i in range(100))
        assert results == want
        assert ex.singles + sum(ex.bulk_batches) == 300, (
            "a batch was submitted twice (or dropped): "
            f"{ex.singles} singles + {ex.bulk_batches}")
    finally:
        done.set()
        run_th.join(timeout=5)


def test_window_flush_fires_without_manual_flush():
    """The persistent flusher honors bulk_window deadlines on its own."""
    ex = ManualExecutor()
    with DataFlowKernel(executors={"manual": ex}, bulk=True,
                        bulk_window=0.005) as dfk:
        futs = [dfk.submit(lambda v=i: v) for i in range(10)]
        assert ex.wait_for(lambda e: sum(e.bulk_batches) == 10, timeout=5), \
            "window deadline never flushed the batch"
        ex.run_pending()
        assert sorted(f.result(timeout=5) for f in futs) == list(range(10))
