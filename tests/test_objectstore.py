"""The data plane: ObjectStore refs, GC, spill, shm transport, and
byte-weighted affinity (docs/dataplane.md).

The hard invariants under test:
  * a published result travels as an ObjectRef and AppFuture.result()
    derefs it transparently (small results stay inline and lock-free);
  * ref-count GC fires exactly once per consumer edge, even when N
    consumers complete concurrently and callers double-release;
  * GC spills before dropping, and a cold deref round-trips from disk;
  * the journal records ref metadata (not the payload) and a restarted
    run re-materializes the result from the spill;
  * the proc transport's shm fast path round-trips large arrays and
    leaks no /dev/shm segment even when workers are SIGKILLed mid-run;
  * affinity_match/remote_bytes weight placement by input bytes, so a
    consumer with one large + many small inputs follows the large one
    (where uid counting picks the wrong pilot);
  * checkpoint pytree leaves dedupe against result spills (one blob).
"""
import concurrent.futures
import glob
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (AppFuture, CostModelPolicy, DataFlowKernel,
                        FaultInjector, LocalityAware, ObjectRef, ObjectStore,
                        PilotDescription, ResourceSpec, RPEXExecutor,
                        TaskRecord, affinity_match, python_app, remote_bytes)
from repro.core.objectstore import estimate_size, materialize


BIG = 256 * 1024        # comfortably above the 64 KiB publish threshold


def _reap_stale_shm():
    """Drop rpxshm segments a previous (crashed) run may have left so the
    no-leak assertions only see this test's segments."""
    for path in glob.glob("/dev/shm/rpxshm*"):
        try:
            os.unlink(path)
        except OSError:
            pass


def _arr(n=BIG // 8):
    return np.arange(n, dtype=np.float64)


# ------------------------------ store unit ------------------------------- #

def test_publish_threshold_and_transparent_deref():
    s = ObjectStore()
    small = s.maybe_publish([1, 2, 3], owner="p0")
    assert small == [1, 2, 3]               # inline: below threshold
    ref = s.maybe_publish(_arr(), owner="p0")
    assert isinstance(ref, ObjectRef)
    assert ref.size == BIG and ref.pilot_uid == "p0"
    assert "ndarray" in ref.kind

    # AppFuture deref is transparent and cached
    f = AppFuture(TaskRecord(uid="t", kind="python", fn=None))
    f.set_result(ref)
    got = f.result()
    assert np.array_equal(got, _arr())
    assert f.quick_result() is got          # cached after first deref
    # inline values keep the lock-free fast path
    f2 = AppFuture(TaskRecord(uid="t2", kind="python", fn=None))
    f2.set_result(41)
    assert f2.quick_result() == 41


def test_same_pilot_deref_is_zero_copy_and_uncounted():
    s = ObjectStore()
    a = _arr()
    ref = s.publish(a, owner="p0")
    assert s.get(ref, pilot_uid="p0") is a  # the very same object
    assert s.stats()["bytes_moved"] == 0
    # cross-pilot: counted once per (object, pilot), not per deref
    s.get(ref, pilot_uid="p1")
    s.get(ref, pilot_uid="p1")
    assert s.stats()["bytes_moved"] == BIG
    s.get(ref, pilot_uid="p2")
    assert s.stats()["bytes_moved"] == 2 * BIG


def test_gc_exactly_once_under_concurrent_release(tmp_path):
    s = ObjectStore(spill_dir=str(tmp_path / "obj"))
    ref = s.publish(_arr(), owner="p0")
    n = 16
    s.add_consumers(ref.oid, n)
    barrier = threading.Barrier(n)

    def consumer():
        barrier.wait()
        s.release(ref.oid)
        s.release(ref.oid)              # double-release must be ignored

    ts = [threading.Thread(target=consumer) for _ in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    e = s.entry(ref.oid)
    assert e.consumers == 0
    assert e.dropped                    # GC'd exactly at zero, not before
    assert s.stats()["spills"] == 1     # spilled once, not per release
    # cold deref re-materializes from the spill
    got = s.get(ref, pilot_uid="p1")
    assert np.array_equal(got, _arr())


def test_spill_round_trip_and_content_dedupe(tmp_path):
    s = ObjectStore(spill_dir=str(tmp_path / "obj"))
    a = _arr()
    r1 = s.publish(a, owner="p0")
    r2 = s.publish(a.copy(), owner="p1")    # byte-identical payload
    s.ensure_spilled(r1.oid)
    s.ensure_spilled(r2.oid)
    assert s.ensure_spilled(r1.oid) == s.ensure_spilled(r2.oid)  # same sha
    blobs = glob.glob(str(tmp_path / "obj" / "blob_*.pkl"))
    assert len(blobs) == 1              # content-addressed: one blob
    assert s.stats()["spills"] == 1


def test_rehost_moves_ownership():
    s = ObjectStore()
    ref = s.publish(_arr(), owner="dead")
    s.get(ref, pilot_uid="live")        # cached on the survivor
    assert s.stats()["bytes_moved"] == BIG
    assert s.rehost("dead", "live") == 1
    e = s.entry(ref.oid)
    assert e.owner == "live"
    # survivor reads are local now; no fresh transfer charge
    s.get(ref, pilot_uid="live")
    assert s.stats()["bytes_moved"] == BIG


def test_materialize_preserves_structure():
    s = ObjectStore()
    ref = s.publish(_arr(), owner="p0")
    args = (1, [ref, 2], {"x": ref})
    out = materialize(args, s)
    assert out[0] == 1
    assert np.array_equal(out[1][0], _arr())
    assert np.array_equal(out[2]["x"], _arr())
    # no refs -> identity (no rebuild on the hot path)
    plain = (1, [2, 3], {"x": 4})
    assert materialize(plain, s) is plain


def test_estimate_size_is_cheap_and_sane():
    assert estimate_size(_arr()) == BIG
    assert estimate_size(b"abcd") == 4
    assert estimate_size({"a": _arr(), "b": 1}) >= BIG
    assert estimate_size(object()) == 32


# --------------------------- end-to-end spine ---------------------------- #

@python_app
def _produce():
    return np.ones(BIG // 8, dtype=np.float64)


@python_app
def _consume(x):
    return float(x.sum())


@pytest.mark.timeout(60)
def test_dfk_edge_bytes_and_release_on_done():
    ex = RPEXExecutor(PilotDescription(name="p0", n_slots=2))
    with DataFlowKernel(executors={"rpex": ex}) as dfk:
        f = _produce()
        g = _consume(f)
        assert g.result() == float(BIG // 8)
        ref = f.raw_result()
        assert isinstance(ref, ObjectRef)
        # per-edge byte accounting
        assert dfk.edge_bytes_total == BIG
        (prod, cons, nbytes), = dfk.edge_bytes
        assert nbytes == BIG
        # the consumer's DONE released the only edge: GC spilled + dropped
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            e = ex.objectstore.entry(ref.oid)
            if e.dropped:
                break
            time.sleep(0.01)
        assert e.dropped
        # the producer's future still resolves (re-materialized)
        assert float(f.result().sum()) == float(BIG // 8)


@pytest.mark.timeout(60)
def test_ref_survives_journal_replay(tmp_path):
    j = str(tmp_path / "pilot.jsonl")

    def run():
        ex = RPEXExecutor(PilotDescription(name="p0", n_slots=2, journal=j))
        with DataFlowKernel(executors={"rpex": ex}, run_id="rr") as dfk:
            return _produce().result(), ex
    v1, ex1 = run()
    # journal line carries ref metadata, never the payload
    with open(j) as fh:
        done = [ln for ln in fh if '"result_ref"' in ln]
    assert done and all('"oid"' in ln for ln in done)
    # the payload is durable next to the journal
    assert glob.glob(str(tmp_path / "pilot.jsonl.obj" / "blob_*.pkl"))
    v2, ex2 = run()                       # restart: replay, no re-execute
    assert np.array_equal(v1, v2)
    assert ex2.pool.pilots[0].store.tasks.keys()  # replayed records exist


# ------------------------------ shm transport ---------------------------- #

@python_app
def _proc_double(a):
    return a * 2.0


@pytest.mark.timeout(120)
def test_shm_round_trip_and_no_leak():
    _reap_stale_shm()
    desc = PilotDescription(name="pp", n_slots=2, transport="proc",
                            shm_threshold=64 * 1024)
    ex = RPEXExecutor(desc)
    with DataFlowKernel(executors={"rpex": ex}):
        a = np.arange(BIG // 8, dtype=np.float64)
        out = _proc_double(a).result()
        assert np.array_equal(out, a * 2.0)
    if os.path.isdir("/dev/shm"):
        assert glob.glob("/dev/shm/rpxshm*") == []


@python_app(retries=3)
def _slow_big(a):
    time.sleep(0.3)
    return a + 1.0


@pytest.mark.timeout(120)
def test_shm_cleanup_after_worker_sigkill():
    """FaultInjector SIGKILLs proc workers mid-run: tasks retry and
    finish, and no shm segment outlives the pool."""
    _reap_stale_shm()
    desc = PilotDescription(name="pk", n_slots=2, transport="proc",
                            shm_threshold=64 * 1024)
    ex = RPEXExecutor(desc, steal=False)
    pool = ex.pool
    inj = FaultInjector(pool, seed=3)
    inj.add_worker_kill(at_s=0.15)
    inj.add_worker_kill(at_s=0.45)
    with DataFlowKernel(executors={"rpex": ex}):
        a = np.arange(BIG // 8, dtype=np.float64)
        inj.start()
        futs = [_slow_big(a) for _ in range(4)]
        for f in futs:
            assert np.array_equal(f.result(), a + 1.0)
        inj.stop()
    assert any(e["kind"] == "worker-kill" and "pid" in e
               for e in inj.events)
    if os.path.isdir("/dev/shm"):
        assert glob.glob("/dev/shm/rpxshm*") == []


# ------------------------- byte-weighted affinity ------------------------ #

def _task_with_bytes(ab):
    t = TaskRecord(uid="t", kind="python", fn=None)
    t.affinity = tuple(ab)
    t.affinity_bytes = dict(ab)
    return t


class _FakePilot:
    def __init__(self, uid, name=None):
        self.uid = uid
        self.desc = type("D", (), {"name": name or uid})()


def test_affinity_match_weights_by_bytes():
    big, small = _FakePilot("pB"), _FakePilot("pS")
    t = _task_with_bytes({"pB": 8 * 1024 * 1024, "pS": 512})
    assert affinity_match(t, big) > 0.99
    assert affinity_match(t, small) < 0.01
    assert remote_bytes(t, big) == 512
    assert remote_bytes(t, small) == 8 * 1024 * 1024
    # legacy uid counting ties them at 0.5 each
    t.affinity_bytes = None
    assert affinity_match(t, big) == affinity_match(t, small) == 0.5


def test_cost_model_prices_transfer_seconds():
    pol = CostModelPolicy(inner=LocalityAware(),
                          bandwidth_bytes_s=1e6)   # 1 MB/s: huge penalty
    t = _task_with_bytes({"pB": 10_000_000, "pS": 100})
    assert remote_bytes(t, _FakePilot("pS")) / pol.bandwidth_bytes_s == \
        pytest.approx(10.0)
    with pytest.raises(ValueError):
        CostModelPolicy(bandwidth_bytes_s=0.0)


@python_app
def _big_producer():
    return np.ones(512 * 1024 // 8, dtype=np.float64)     # 512 KiB


@python_app
def _small_producer():
    return np.ones(65_536 // 8, dtype=np.float64)         # 64 KiB (published)


@python_app
def _sink(big, *smalls):
    return float(big.sum()) + sum(float(s.sum()) for s in smalls)


def _placement_run(byte_affinity: bool):
    """One large producer pinned on p1, three small ones pinned on p0; the
    consumer should follow the bytes (p1) — uid counting follows the
    count (p0)."""
    ex = RPEXExecutor([PilotDescription(name="p0", n_slots=4),
                       PilotDescription(name="p1", n_slots=4)],
                      steal=False,
                      placement=LocalityAware(locality_weight=10.0))
    res_p0 = ResourceSpec(slots=1, cpu_only=True, sticky=True,
                          affinity=("p0",))
    res_p1 = ResourceSpec(slots=1, cpu_only=True, sticky=True,
                          affinity=("p1",))
    with DataFlowKernel(executors={"rpex": ex},
                        byte_affinity=byte_affinity) as dfk:
        smalls = [dfk.submit(_small_producer.__wrapped_app__, (),
                             resources=res_p0) for _ in range(3)]
        big = dfk.submit(_big_producer.__wrapped_app__, (),
                         resources=res_p1)
        # drain producers fully so the sink routes against idle, equal
        # loads — the affinity term alone decides
        concurrent.futures.wait(smalls + [big])
        ex.drain(timeout=10.0)
        sink = dfk.submit(_sink.__wrapped_app__, (big, *smalls))
        sink.result()
        return sink.task.pilot_uid, {p.desc.name: p.uid
                                     for p in ex.pool.pilots}


@pytest.mark.timeout(120)
def test_byte_weighted_placement_follows_largest_input():
    uid, pilots = _placement_run(byte_affinity=True)
    assert uid == pilots["p1"]          # follows the 512 KiB input
    uid, pilots = _placement_run(byte_affinity=False)
    assert uid == pilots["p0"]          # uid counting: 3 hints beat 1


# --------------------------- checkpoint dedupe --------------------------- #

@pytest.mark.timeout(60)
def test_checkpoint_leaf_dedupes_against_result_spill(tmp_path):
    j = str(tmp_path / "c.jsonl")

    @python_app(checkpointable=True)
    def work(ckpt=None):
        state = np.ones(BIG // 8, dtype=np.float64)
        ckpt.save(0, state)             # leaf == the final result
        return state

    ex = RPEXExecutor(PilotDescription(name="c", n_slots=2, journal=j))
    with DataFlowKernel(executors={"rpex": ex}, run_id="cd") as dfk:
        out = work().result()
        assert float(out.sum()) == float(BIG // 8)
        # force the result spill through the journal writer
        assert ex.pool.pilots[0].store.flush()
    blobs = glob.glob(str(tmp_path / "c.jsonl.obj" / "blob_*.pkl"))
    # checkpoint leaf and spilled result are byte-identical -> one blob
    assert len(blobs) == 1
