"""Cost-model scheduling: the StateStore duration model (EWMA mean/var
per app kind, journal replay + compaction round-trip, cold-start
fallback), CostModelPolicy's predicted-seconds decisions, the agents'
per-kind straggler deadlines (the mixed-kind regression), and the
predictive PoolScaler/seeding plumbing."""
import json
import threading
import time

import pytest

from repro.core import (CostModelPolicy, LeastLoaded, LocalityAware,
                        Pilot, PilotDescription, PilotPool, PoolScaler,
                        ResourceSpec, ScalerConfig, StateStore, TaskRecord,
                        model_kind, resolve_policy, translate)


def _ewma_ref(xs, alpha=0.2):
    """Offline reference for the store's West EWMA recurrence."""
    mean, var = xs[0], 0.0
    for x in xs[1:]:
        d = x - mean
        incr = alpha * d
        mean += incr
        var = (1.0 - alpha) * (var + d * incr)
    return mean, var, len(xs)


def _write_journal(path, task_timelines):
    """Synthetic journal: one line per transition with controlled
    monotonic stamps, exactly as record() lays them down.  Each timeline
    is (uid, kind, akind, [(state, mt), ...])."""
    off = time.time() - time.monotonic()
    with open(path, "w") as fh:
        for uid, kind, akind, steps in task_timelines:
            for state, mt in steps:
                rec = {"uid": uid, "key": None, "kind": kind,
                       "state": state, "retries": 0, "slot_ids": [0],
                       "t": mt + off, "mt": mt}
                if akind is not None:
                    rec["akind"] = akind
                fh.write(json.dumps(rec) + "\n")


# --------------------------- duration model ------------------------------ #

def test_ewma_incremental_matches_offline_reference():
    st = StateStore()
    xs = [1.0, 2.0, 4.0, 0.5, 3.0]
    with st._lock:
        for x in xs:
            st._dur_update("k", x)
    mean, var, n = st.duration_stats("k")
    rm, rv, rn = _ewma_ref(xs)
    assert (mean, n) == (pytest.approx(rm), rn)
    assert var == pytest.approx(rv)


def test_replay_rebuilds_model_from_running_done_stamps(tmp_path):
    """Journal replay feeds the model the same RUNNING->DONE samples the
    live _ingest path saw: controlled stamps give exact durations."""
    j = tmp_path / "j.jsonl"
    base = time.monotonic()
    tls = []
    durs = [1.0, 2.0, 4.0]
    for i, d in enumerate(durs):
        t0 = base + i * 10
        tls.append((f"t.{i}", "python", None,
                    [("SCHEDULED", t0 - 0.01), ("RUNNING", t0),
                     ("DONE", t0 + d)]))
    # a bash app executes as kind "python" but models under its app kind
    tls.append(("t.b", "python", "bash",
                [("RUNNING", base + 100), ("DONE", base + 100.5)]))
    # FAILED leaves no sample; the retry measures from its *latest*
    # RUNNING stamp, not the first
    tls.append(("t.r", "python", None,
                [("RUNNING", base + 200), ("FAILED", base + 209),
                 ("RUNNING", base + 210), ("DONE", base + 211.5)]))
    _write_journal(j, tls)
    st = StateStore(str(j))
    try:
        rm, rv, rn = _ewma_ref(durs + [1.5])      # t.r contributes 1.5s
        mean, var, n = st.duration_stats("python")
        assert n == rn
        assert mean == pytest.approx(rm)
        assert var == pytest.approx(rv)
        assert st.duration_stats("bash") == (pytest.approx(0.5), 0.0, 1)
    finally:
        st.close()


def test_compaction_snapshots_and_reseeds_model(tmp_path):
    """The model survives journal compaction via the stats header, and a
    restart on the compacted journal merges it back losslessly."""
    j = tmp_path / "j.jsonl"
    st = StateStore(str(j), compact_min_lines=4, compact_factor=1)
    st.seed_durations("spmd", 2.0, 0.25, 8)
    st.seed_durations("bash", 0.1, 0.0, 3)
    # enough non-sampling transitions to trip compaction (no RUNNING->DONE
    # pairs, so the model stays exactly the seeded values)
    for i in range(16):
        t = TaskRecord(uid=f"t.{i}", kind="python")
        from repro.core import TaskState
        t.transition(TaskState.TRANSLATED, st)
        t.transition(TaskState.SCHEDULED, st)
    assert st.flush(timeout=10.0)
    st.close()
    txt = j.read_text().splitlines()
    head = json.loads(txt[0])
    assert head.get("event") == "_SNAPSHOT"
    assert head["stats"]["dur"]["spmd"] == [2.0, 0.25, 8]

    st2 = StateStore(str(j))
    try:
        assert st2.duration_stats("spmd") == (2.0, 0.25, 8)
        assert st2.duration_stats("bash") == (0.1, 0.0, 3)
    finally:
        st2.close()


def test_cold_start_returns_none_and_pooled_mixture():
    st = StateStore()
    assert st.duration_stats("anything") is None
    assert st.duration_stats(None) is None
    assert st.duration_model() == {}
    st.seed_durations("a", 1.0, 0.0, 1)
    st.seed_durations("b", 3.0, 0.0, 3)
    mean, var, n = st.duration_stats(None)      # n-weighted pool
    assert n == 4
    assert mean == pytest.approx((1.0 + 3.0 * 3) / 4)
    assert var == pytest.approx((1 * (2.5 - 1.0) ** 2
                                 + 3 * (2.5 - 3.0) ** 2) / 4 + 0.0)
    assert st.duration_stats("a") == (1.0, 0.0, 1)


def test_seed_durations_merges_n_weighted():
    st = StateStore()
    st.seed_durations("k", 1.0, 0.0, 2)
    st.seed_durations("k", 3.0, 0.0, 2)
    mean, var, n = st.duration_stats("k")
    assert (mean, n) == (2.0, 4)
    assert var == pytest.approx(1.0)            # between-source spread kept


# ------------------------- CostModelPolicy ------------------------------- #

def _kinded(name, body=None):
    fn = body or (lambda: 1)
    fn.__app_kind__ = name
    return fn


def _translate_kind(kind, **res):
    t = translate(_kinded(kind), (), {},
                  ResourceSpec(**res) if res else None)
    return t


def test_resolve_cost_policy_names_and_validation():
    p = resolve_policy("cost")
    assert isinstance(p, CostModelPolicy)
    assert isinstance(p.inner, LeastLoaded)
    p2 = CostModelPolicy(inner="locality")
    assert isinstance(p2.inner, LocalityAware)
    with pytest.raises(ValueError, match="wrap itself"):
        CostModelPolicy(inner=CostModelPolicy())
    with pytest.raises(ValueError, match="default_duration_s"):
        CostModelPolicy(default_duration_s=0.0)


def test_cold_model_degenerates_to_count_based_ranking():
    """With no samples anywhere, every pilot prices at the constant
    default and the cost ranking equals LeastLoaded's."""
    pool = PilotPool([PilotDescription(n_slots=2, name="a"),
                      PilotDescription(n_slots=2, name="b")],
                     steal=False, policy=CostModelPolicy())
    try:
        gate = threading.Event()
        a, b = pool.pilots
        for _ in range(3):              # load a: 3 gated blockers
            a.agent.submit(translate(lambda: gate.wait(15), (), {}))
        probe = translate(lambda: 1, (), {})
        assert pool.route(probe) is b   # least loaded, priced constant
        gate.set()
    finally:
        gate.set()
        pool.close()


def test_place_prefers_fewer_predicted_seconds_over_fewer_slots():
    """Two queued long tasks must repel a probe harder than four queued
    short ones — the core slot-count-vs-seconds inversion."""
    pool = PilotPool([PilotDescription(n_slots=1, name="a"),
                      PilotDescription(n_slots=1, name="b")],
                     steal=False, preempt=False, policy=CostModelPolicy())
    try:
        a, b = pool.pilots
        for p in (a, b):
            p.store.seed_durations("long", 5.0, 0.0, 10)
            p.store.seed_durations("short", 0.01, 0.0, 10)
            p.store.seed_durations("probe", 0.01, 0.0, 10)
        gate = threading.Event()
        for _ in range(2):              # a: ~10s of predicted backlog
            a.agent.submit(translate(
                _kinded("long", lambda: gate.wait(15)), (), {}))
        for _ in range(4):              # b: ~0.04s predicted, 2x the slots
            b.agent.submit(translate(
                _kinded("short", lambda: gate.wait(15)), (), {}))
        time.sleep(0.05)
        probe = _translate_kind("probe")
        assert pool.route(probe) is b                    # cost: pick b
        assert LeastLoaded().place(probe, [a, b]) is a   # counts: pick a
        gate.set()
    finally:
        gate.set()
        pool.close()


def test_place_bulk_accumulates_batch_seconds():
    """Bulk placement spreads by predicted seconds: after a long task
    lands on the emptier pilot, the next long task must go to the other
    one even though the first pilot still has fewer queued slots."""
    pool = PilotPool([PilotDescription(n_slots=1, name="a"),
                      PilotDescription(n_slots=1, name="b")],
                     steal=False, preempt=False, policy=CostModelPolicy())
    try:
        a, b = pool.pilots
        for p in (a, b):
            p.store.seed_durations("long", 5.0, 0.0, 10)
        tasks = [_translate_kind("long") for _ in range(4)]
        got = pool.route_bulk(tasks)
        assert {g.uid for g in got[:2]} == {a.uid, b.uid}   # alternates
        assert {g.uid for g in got[2:]} == {a.uid, b.uid}
    finally:
        pool.close()


def test_pick_victim_orders_by_backlog_seconds():
    pool = PilotPool([PilotDescription(n_slots=1, name="thief"),
                      PilotDescription(n_slots=1, name="a"),
                      PilotDescription(n_slots=1, name="b")],
                     steal=False, preempt=False, policy=CostModelPolicy())
    try:
        thief, a, b = pool.pilots
        for p in (a, b):
            p.store.seed_durations("long", 5.0, 0.0, 10)
            p.store.seed_durations("short", 0.01, 0.0, 10)
        gate = threading.Event()
        for _ in range(2):
            a.agent.submit(translate(
                _kinded("long", lambda: gate.wait(15)), (), {}))
        for _ in range(5):
            b.agent.submit(translate(
                _kinded("short", lambda: gate.wait(15)), (), {}))
        time.sleep(0.05)
        demand = {a.uid: a.agent.queued_demand(),
                  b.uid: b.agent.queued_demand()}
        assert demand[b.uid] > demand[a.uid]    # counts say b first
        order = pool.policy.pick_victim(thief, [a, b], demand)
        assert order[0] is a                    # seconds say a first
        gate.set()
    finally:
        gate.set()
        pool.close()


def test_steal_eligibility_prices_affinity_in_seconds():
    policy = CostModelPolicy(inner=LocalityAware(locality_weight=0.5))
    pool = PilotPool([PilotDescription(n_slots=1, name="thief"),
                      PilotDescription(n_slots=1, name="victim")],
                     steal=False, preempt=False, policy=policy)
    try:
        thief, victim = pool.pilots
        victim.store.seed_durations("long", 10.0, 0.0, 10)
        task = _translate_kind("long")
        task.affinity = (victim.uid,)
        # penalty = 0.5 weight * 10s run * 1.0 affinity lost = 5s; an
        # imbalance worth < 5s of victim backlog must not move the task
        assert not policy.steal_eligible(task, thief, victim,
                                         imbalance=0.4)   # 0.4*10s = 4s
        assert policy.steal_eligible(task, thief, victim,
                                     imbalance=0.6)       # 6s > 5s
        # a task with no affinity always moves (penalty <= 0)
        free = _translate_kind("long")
        assert policy.steal_eligible(free, thief, victim, imbalance=0.0)
    finally:
        pool.close()


def test_pick_preempt_spares_nearly_done_task():
    """The default policy preempts the longest-running task — exactly
    the one about to finish.  The cost model ranks by predicted
    *remaining* seconds, so the fresh task is the victim instead."""
    policy = CostModelPolicy()
    pool = PilotPool([PilotDescription(n_slots=2, name="thief"),
                      PilotDescription(n_slots=2, name="victim")],
                     steal=False, preempt=False, policy=policy)
    try:
        thief, victim = pool.pilots
        victim.store.seed_durations("work", 10.0, 0.0, 10)
        now = time.monotonic()
        nearly_done = _translate_kind("work")
        nearly_done.timestamps["RUNNING"] = now - 9.0     # 1s remaining
        fresh = _translate_kind("work")
        fresh.timestamps["RUNNING"] = now - 1.0           # 9s remaining
        cands = [(nearly_done, victim), (fresh, victim)]
        loads = {victim.uid: 1.0}
        got, _ = policy.pick_preempt(thief, cands, loads)
        assert got is fresh
        base, _ = LeastLoaded().pick_preempt(thief, cands, loads)
        assert base is nearly_done      # the inversion being fixed
    finally:
        pool.close()


# ---------------------- per-kind straggler deadlines --------------------- #

def _mk_pilot(per_kind=True, **kw):
    return Pilot(PilotDescription(n_slots=2, per_kind_deadlines=per_kind,
                                  **kw))


def test_per_kind_deadline_uses_kind_model():
    p = _mk_pilot(straggler_factor=3.0, straggler_stdev_k=4.0)
    try:
        p.store.seed_durations("slow", 2.0, 0.04, 10)
        dl = p.agent._deadline("slow")
        assert dl == pytest.approx(max(0.1, 6.0, 2.0 + 4.0 * 0.2))
        # a cold kind falls back to the global path (None: no samples)
        assert p.agent._deadline("never-seen") is None
    finally:
        p.close()


def test_per_kind_deadline_disabled_ignores_model():
    p = _mk_pilot(per_kind=False)
    try:
        p.store.seed_durations("slow", 2.0, 0.0, 10)
        assert p.agent._deadline("slow") is None    # global deque is cold
    finally:
        p.close()


def _run_mixed_kind_straggler(per_kind: bool) -> int:
    """Flood a fast kind to drag the global p95 to the floor, then run
    one normal slow-kind task; return how many replicas spawned."""
    p = Pilot(PilotDescription(n_slots=2, per_kind_deadlines=per_kind,
                              straggler_factor=3.0))
    try:
        # the slow kind's population is well-known: mean 0.15s
        p.store.seed_durations("slow", 0.15, 1e-6, 10)
        done = threading.Event()
        n_fast = 60
        left = [n_fast]
        lock = threading.Lock()

        def _one_done(t):
            with lock:
                left[0] -= 1
                if left[0] == 0:
                    done.set()
        for _ in range(n_fast):         # global p95 -> ~2ms * 3 (floored)
            p.agent.submit(translate(
                _kinded("fast", lambda: time.sleep(0.002)), (), {}),
                done_cb=_one_done)
        assert done.wait(30)
        probe_done = threading.Event()
        probe = translate(
            _kinded("slow", lambda: time.sleep(0.3)), (), {})
        p.agent.submit(probe, done_cb=lambda t: probe_done.set())
        assert probe_done.wait(30)
        time.sleep(0.1)                 # let any late monitor tick land
        return sum(1 for uid in p.store.states()
                   if uid.startswith("replica."))
    finally:
        p.close()


@pytest.mark.timeout(120)
def test_mixed_kind_flood_spawns_no_spurious_replicas():
    """The tentpole regression: a fast kind's flood drags the global p95
    below a slow kind's normal runtime.  Per-kind deadlines judge the
    slow task against its own population (0.45s deadline vs 0.3s run: no
    replica); the old global path replicates it spuriously."""
    assert _run_mixed_kind_straggler(per_kind=True) == 0


@pytest.mark.timeout(120)
def test_mixed_kind_flood_global_baseline_still_replicates():
    """Pin the bug the per-kind fix removes: with per_kind_deadlines off
    the same scenario must still spawn a spurious replica — if this ever
    stops failing-by-design, the regression test above has lost its
    discriminating power."""
    assert _run_mixed_kind_straggler(per_kind=False) >= 1


# -------------------- predictive scaling + seeding ----------------------- #

def test_predicted_queue_wait_prices_queued_kinds():
    p = Pilot(PilotDescription(n_slots=2))
    try:
        assert p.predicted_queue_wait() == 0.0
        p.store.seed_durations("slow", 2.0, 0.0, 10)
        gate = threading.Event()
        for _ in range(6):              # 2 run, 4 queue
            p.agent.submit(translate(
                _kinded("slow", lambda: gate.wait(15)), (), {}))
        time.sleep(0.05)
        queued = sum(p.agent.queued_by_kind().values())
        assert queued == 4
        assert p.predicted_queue_wait() == pytest.approx(
            queued * 2.0 / 2, rel=1e-6)
        gate.set()
    finally:
        gate.set()
        p.close()


def test_scaler_wait_signal_predictive_vs_observed():
    pool = PilotPool([PilotDescription(n_slots=1)], steal=False,
                     preempt=False)
    try:
        p = pool.pilots[0]
        p.store.seed_durations("slow", 3.0, 0.0, 10)
        gate = threading.Event()
        for _ in range(3):              # 1 runs, 2 queue: 6s predicted
            p.agent.submit(translate(
                _kinded("slow", lambda: gate.wait(15)), (), {}))
        time.sleep(0.05)
        now = time.monotonic()
        on = PoolScaler(pool, ScalerConfig(predictive=True))
        off = PoolScaler(pool, ScalerConfig(predictive=False))
        assert on._wait_signal(p, now) >= 6.0 - 1e-6
        assert off._wait_signal(p, now) < 1.0     # observed wait only
        gate.set()
    finally:
        gate.set()
        pool.close()


def test_add_pilot_seeds_model_from_siblings():
    pool = PilotPool([PilotDescription(n_slots=1, name="a"),
                      PilotDescription(n_slots=1, name="b")],
                     steal=False, preempt=False)
    try:
        a, b = pool.pilots
        a.store.seed_durations("k", 2.0, 0.0, 4)
        b.store.seed_durations("k", 4.0, 0.0, 4)
        fresh = pool.add_pilot(PilotDescription(n_slots=1, name="c"))
        mean, _var, n = fresh.store.duration_stats("k")
        assert n == 8
        assert mean == pytest.approx(3.0)       # n-weighted across both
        cold = pool.add_pilot(PilotDescription(n_slots=1, name="d"),
                              seed_durations=False)
        assert cold.store.duration_stats("k") is None
    finally:
        pool.close()


def test_model_kind_prefers_app_kind():
    t = TaskRecord(uid="x", kind="python", app_kind="bash")
    assert model_kind(t) == "bash"
    t2 = TaskRecord(uid="y", kind="python")
    assert model_kind(t2) == "python"
