"""The concurrency analyzers, tested on seeded defects.

Each fixture module below contains exactly one known bug class; the
corresponding rule code must fire on it and must NOT fire on the clean
twin.  This is the analyzer's own regression suite — if a refactor of
the AST walkers stops catching the seeded deadlock, this file fails
before the real runtime quietly loses its safety net.

The watchdog tests drive the recording machinery directly with wrapped
locks (no global install), plus one install()/uninstall() round-trip
exercising the allocation-site filter and the TaskRecord validation
hook.
"""
import textwrap
import threading
import time

import pytest

from repro.analysis import apply_baseline, load_baseline
from repro.analysis.events import (analyze_events, analyze_state_machine,
                                   extract_registry)
from repro.analysis.locks import analyze_lock_discipline
from repro.analysis.watchdog import (LockWatchdog, _WrappedCondition,
                                     _WrappedLock, check_snapshot, install,
                                     uninstall)


def _src(text):
    return textwrap.dedent(text)


def _codes(findings):
    return sorted(f.code for f in findings)


# ---------------------------- lock discipline --------------------------- #

LOCK_CYCLE = _src("""
    import threading

    class S:
        def __init__(self):
            self.a = threading.Lock()
            self.b = threading.Lock()

        def fwd(self):
            with self.a:
                with self.b:
                    pass

        def rev(self):
            with self.b:
                with self.a:
                    pass
""")

LOCK_CYCLE_CROSS_METHOD = _src("""
    import threading

    class S:
        def __init__(self):
            self.a = threading.Lock()
            self.b = threading.Lock()

        def fwd(self):
            with self.a:
                self._inner()

        def _inner(self):
            with self.b:
                pass

        def rev(self):
            with self.b:
                with self.a:
                    pass
""")

SELF_DEADLOCK = _src("""
    import threading

    class S:
        def __init__(self):
            self.a = threading.Lock()

        def oops(self):
            with self.a:
                with self.a:
                    pass
""")

BLOCKING_PICKLE = _src("""
    import pickle
    import threading

    class S:
        def __init__(self):
            self.lk = threading.Lock()

        def save(self, obj, fh):
            with self.lk:
                data = pickle.dumps(obj)
                fh.write(data)
""")

UNGUARDED_WAIT = _src("""
    import threading

    class S:
        def __init__(self):
            self.cv = threading.Condition()
            self.ready = False

        def bad(self):
            with self.cv:
                self.cv.wait(1.0)

        def good(self):
            with self.cv:
                while not self.ready:
                    self.cv.wait(1.0)

        def also_good(self):
            with self.cv:
                self.cv.wait_for(lambda: self.ready, 1.0)
""")

CLEAN_LOCKS = _src("""
    import pickle
    import threading

    class S:
        def __init__(self):
            self.a = threading.Lock()
            self.b = threading.Lock()

        def fwd(self):
            with self.a:
                with self.b:
                    pass

        def also_fwd(self):
            with self.a:
                with self.b:
                    pass

        def save(self, obj, fh):
            with self.a:
                obj = dict(obj)
            data = pickle.dumps(obj)
            fh.write(data)
""")


def test_lock_cycle_same_method_rpx001():
    findings, graph = analyze_lock_discipline({"fix/cycle.py": LOCK_CYCLE})
    assert "RPX001" in _codes(findings)
    cyc = [f for f in findings if f.code == "RPX001"]
    assert any("cycle" in f.message for f in cyc)


def test_lock_cycle_through_self_call_rpx001():
    findings, _ = analyze_lock_discipline(
        {"fix/xcycle.py": LOCK_CYCLE_CROSS_METHOD})
    assert "RPX001" in _codes(findings)


def test_nonreentrant_reacquire_rpx001():
    findings, _ = analyze_lock_discipline({"fix/selfdl.py": SELF_DEADLOCK})
    sd = [f for f in findings if f.code == "RPX001"]
    assert sd and any("re-acquire" in f.message or "self" in f.message
                      for f in sd)


def test_blocking_pickle_under_lock_rpx002():
    findings, _ = analyze_lock_discipline({"fix/pkl.py": BLOCKING_PICKLE})
    hits = [f for f in findings if f.code == "RPX002"]
    # both pickle.dumps and fh.write happen under the lock
    assert len(hits) == 2
    assert all("lk" in f.message for f in hits)


def test_unguarded_wait_rpx003_and_clean_waits_pass():
    findings, _ = analyze_lock_discipline({"fix/wait.py": UNGUARDED_WAIT})
    hits = [f for f in findings if f.code == "RPX003"]
    assert len(hits) == 1                  # only S.bad; good/also_good clean
    assert "bad" in hits[0].key


def test_clean_module_has_no_lock_findings():
    findings, graph = analyze_lock_discipline({"fix/clean.py": CLEAN_LOCKS})
    assert findings == []
    # the consistent a->b order is still recorded in the graph
    assert any(e.src[1] == "a" and e.dst[1] == "b" for e in graph.edges)


def test_syntax_error_is_reported_not_swallowed():
    findings, _ = analyze_lock_discipline({"fix/broken.py": "def f(:\n"})
    assert _codes(findings) == ["RPX000"]


# ---------------------------- event protocol ---------------------------- #

REGISTRY = _src("""
    class EVENTS:
        PING = "PING"
        PONG = "PONG"
""")

EMIT_ONLY = _src("""
    def emit(store):
        store.record_event("PING", n=1)
""")

CONSUME_ONLY = _src("""
    def replay(events):
        return [e for e in events if e["event"] == "PONG"]
""")

UNDECLARED = _src("""
    def emit(store):
        store.record_event("ZING", n=1)

    def replay(events):
        return [e for e in events if e["event"] == "ZING"]
""")

CLEAN_PAIR = _src("""
    def emit(store):
        store.record_event("PING", n=1)

    def replay(events):
        return [e for e in events if e["event"] == "PING"]
""")


def test_emitted_never_consumed_rpx004():
    f = analyze_events({"reg.py": REGISTRY, "emit.py": EMIT_ONLY})
    assert "RPX004:PING" in {x.key for x in f}


def test_consumed_never_emitted_rpx005():
    f = analyze_events({"reg.py": REGISTRY, "cons.py": CONSUME_ONLY})
    assert "RPX005:PONG" in {x.key for x in f}


def test_undeclared_event_name_rpx006():
    f = analyze_events({"reg.py": REGISTRY, "bad.py": UNDECLARED})
    assert "RPX006:ZING" in {x.key for x in f}


def test_missing_registry_rpx006():
    f = analyze_events({"emit.py": EMIT_ONLY})
    assert "RPX006:<no-registry>" in {x.key for x in f}


def test_clean_event_pair_passes():
    f = analyze_events({"reg.py": REGISTRY, "ok.py": CLEAN_PAIR})
    assert f == []


def test_events_attr_references_resolve_through_registry():
    emit = _src("""
        from mod import EVENTS

        def emit(store):
            store.record_event(EVENTS.PING, n=1)

        def replay(events):
            return [e for e in events if e["event"] == EVENTS.PING]
    """)
    assert extract_registry({"reg.py": REGISTRY}) == {"PING": "PING",
                                                      "PONG": "PONG"}
    f = analyze_events({"reg.py": REGISTRY, "emit.py": emit})
    assert f == []


# ---------------------------- state machine ----------------------------- #

MACHINE = _src("""
    class TaskState:
        NEW = "NEW"
        DONE = "DONE"
        LOST = "LOST"

    STATE_MACHINE = {
        TaskState.NEW: (TaskState.DONE,),
        TaskState.DONE: (),
        TaskState.LOST: (),
    }
""")


def test_transition_without_inbound_edge_rpx007():
    use = _src("""
        def f(task):
            task.transition(TaskState.LOST)
    """)
    f = analyze_state_machine({"m.py": MACHINE, "u.py": use})
    assert any(x.key == "RPX007:u:f:LOST" for x in f)


def test_declared_transition_passes():
    use = _src("""
        def f(task):
            task.transition(TaskState.DONE)
    """)
    assert analyze_state_machine({"m.py": MACHINE, "u.py": use}) == []


def test_machine_member_drift_rpx007():
    bad = MACHINE.replace("    TaskState.LOST: (),\n", "")
    assert bad != MACHINE
    f = analyze_state_machine({"m.py": bad})
    assert any(x.key == "RPX007:machine:LOST" for x in f)


def test_missing_machine_rpx007():
    lone = _src("""
        class TaskState:
            NEW = "NEW"
    """)
    f = analyze_state_machine({"m.py": lone})
    assert any(x.key == "RPX007:machine:<missing>" for x in f)


# ------------------------------- baseline ------------------------------- #

def test_baseline_suppresses_and_reports_stale(tmp_path):
    bl = tmp_path / "baseline.txt"
    bl.write_text(
        "# comment line\n"
        "RPX002:pkl:S.save:pickle.dumps  # leaf lock, documented\n"
        "RPX001:gone:X.y:stale  # fixed long ago\n")
    entries = load_baseline(bl)
    assert entries["RPX002:pkl:S.save:pickle.dumps"] == \
        "leaf lock, documented"
    findings, _ = analyze_lock_discipline({"fix/pkl.py": BLOCKING_PICKLE})
    pkl = [f for f in findings if f.key.endswith("pickle.dumps")]
    new, suppressed, stale = apply_baseline(pkl, entries)
    assert new == []
    assert suppressed == ["RPX002:pkl:S.save:pickle.dumps"]
    assert stale == ["RPX001:gone:X.y:stale"]


def test_repo_gate_is_green():
    """The committed baseline covers the live tree: the same entry point
    CI runs must pass here."""
    from repro.analysis.__main__ import main
    assert main([]) == 0


# ------------------------------- watchdog ------------------------------- #

def _wrapped_pair(wd):
    a = _WrappedLock(threading.Lock(), "mod.py:10", wd)
    b = _WrappedLock(threading.Lock(), "mod.py:20", wd)
    return a, b


def test_watchdog_consistent_order_is_clean():
    wd = LockWatchdog()
    a, b = _wrapped_pair(wd)
    for _ in range(3):
        with a:
            with b:
                pass
    snap = wd.snapshot()
    assert snap["cycles"] == []
    assert snap["edge_count"] == 1
    assert wd.check() == []


def test_watchdog_opposite_order_across_threads_rpx008():
    wd = LockWatchdog()
    a, b = _wrapped_pair(wd)
    # interleave for real: two threads, barriers between the conflicting
    # critical sections so both orders are actually recorded
    def fwd():
        with a:
            with b:
                pass

    def rev():
        with b:
            with a:
                pass

    t1 = threading.Thread(target=fwd)
    t1.start(); t1.join()
    t2 = threading.Thread(target=rev)
    t2.start(); t2.join()
    findings = wd.check()
    assert [f.code for f in findings] == ["RPX008"]
    assert "mod.py:10" in findings[0].message


def test_watchdog_rlock_reentry_is_not_an_edge():
    wd = LockWatchdog()
    r = _WrappedLock(threading.RLock(), "mod.py:30", wd)
    with r:
        with r:
            pass
    snap = wd.snapshot()
    assert snap["edge_count"] == 0
    assert snap["cycles"] == []


def test_watchdog_hold_ceiling_rpx009():
    wd = LockWatchdog()
    a, _ = _wrapped_pair(wd)
    with a:
        time.sleep(0.05)
    findings = wd.check(hold_ceiling_s=0.01)
    assert [f.code for f in findings] == ["RPX009"]
    assert wd.check(hold_ceiling_s=5.0) == []


def test_watchdog_condition_wait_excluded_from_hold():
    wd = LockWatchdog()
    cv = _WrappedCondition(threading.Condition(), "mod.py:40", wd)
    with cv:
        cv.wait(0.05)                     # parked: lock genuinely free
    snap = wd.snapshot()
    assert snap["max_hold_ms"]["mod.py:40"] < 40


def test_watchdog_transition_violation_rpx007():
    wd = LockWatchdog()
    wd.on_transition("DONE", "RUNNING", "task.000001")
    findings = wd.check()
    assert [f.code for f in findings] == ["RPX007"]
    assert "DONE -> RUNNING" in findings[0].message


def test_check_snapshot_round_trips_saved_report():
    snap = {
        "cycles": [["x.py:1", "y.py:2"]],
        "max_hold_ms": {"x.py:1": 5000.0},
        "transition_violations": [
            {"uid": "t", "from": "DONE", "to": "NEW"}],
    }
    codes = sorted(f.code for f in check_snapshot(snap, hold_ceiling_s=2.0))
    assert codes == ["RPX007", "RPX008", "RPX009"]


def test_install_filters_by_allocation_site():
    """install() wraps locks allocated from repro source files only;
    stdlib-internal allocations (threading.Event) keep real primitives,
    and an illegal TaskRecord transition is recorded."""
    from repro.analysis import watchdog as wdmod
    from repro.core.futures import TaskState
    from repro.core.translator import translate
    if wdmod.active() is not None:
        pytest.skip("watchdog already installed session-wide "
                    "(REPRO_LOCK_WATCHDOG=1); install() path covered "
                    "by the instrumented run itself")
    wd = install()
    try:
        fake = compile("import threading\nlk = threading.Lock()\n",
                       "/x/repro/core/fake.py", "exec")
        ns = {}
        exec(fake, ns)
        assert isinstance(ns["lk"], _WrappedLock)
        assert ns["lk"]._site == "core/fake.py:2"
        with ns["lk"]:
            pass
        assert wd.acquisitions == {"core/fake.py:2": 1}
        ev = threading.Event()             # allocated inside threading.py
        ev.set(); ev.clear()               # must behave like a real Event
        assert not isinstance(ev._cond, _WrappedCondition)

        t = translate(lambda: 1, (), {})
        t.transition(TaskState.DONE)
        t.transition(TaskState.RUNNING)    # illegal: DONE is terminal
        assert any(v["from"] == "DONE" and v["to"] == "RUNNING"
                   for v in wd.transition_violations)
    finally:
        uninstall()
    assert threading.Lock is not ns["lk"].__class__
    assert not isinstance(threading.Lock(), _WrappedLock)
